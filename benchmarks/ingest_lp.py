"""Ingestion benchmark: host-staged vs device-resident kNN candidate
search feeding the same streaming LP engine.

Two arms replay ONE pre-generated embedding stream (so their graphs are
comparable bit-for-bit) through ``StreamEngine``:

  * ``host``    — ``ingest="host"``: the staging path this PR's device
                  pipeline replaces.  Candidate search runs
                  ``graph.knn.build_knn_graph`` on the host per batch.
  * ``device``  — ``ingest="device"``: embeddings land in the
                  device-resident ``EmbeddingStore`` and one fused
                  ``kernels.argkmin`` pass per batch returns the new
                  rows' candidate supersets plus the displaced-row set.

Each arm seeds a mixed insert/delete/mostly-labeled stream (growing the
graph through several bucket rungs, so rung-crossing compiles are paid
up front), then times a steady-state all-labeled insert phase —
"embeddings in → labels committed" throughput, the number the ROADMAP
ingestion item is about.  Arms run interleaved best-of-``ROUNDS``
(the stream_throughput precedent: scheduler drift hits both alike).

``--check`` gates the recorded floors:

  * device throughput ≥ ``DEVICE_OVER_REFERENCE_FLOOR`` x the recorded
    ``HOST_STAGING_OPS_PER_SEC`` reference (the acceptance headline);
  * the live host arm still clears the recorded reference (provenance
    stays conservative);
  * kernel-vs-oracle agreement == 1.0 — the device arm's final graph
    (labels, adjacency, edges) is BIT-IDENTICAL to the host oracle's,
    the ``graph.knn`` module-docstring contract measured end to end;
  * compile-once: engine recompiles ≤ the snapshot ladder bound, and
    the ingest path's jit entries ≤ ``ingest_ladder_bound`` — stream
    length never shows up in either cache.

Single-device by design (``REPRO_FORCE_HOST_DEVICES`` is deliberately
not applied): the 8-virtual-device bit-identity of the device ingest
path is proven by tests/test_stream_sharded.py; this benchmark measures
the ingest arms without mesh staging noise.  On a CPU-only host both
arms share the same silicon, so the live host arm (sped up by the same
graph-merge work) is the agreement oracle while the *recorded* 200
ops/s reference carries the cross-PR throughput claim.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

try:
    from benchmarks.common import check_gate as _gate, finish_checks
except ImportError:  # run as a script: sys.path[0] is benchmarks/ itself
    from common import check_gate as _gate, finish_checks

from repro.core.snapshot import ladder_size
from repro.core.stream import StreamEngine
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.ingest.incremental_knn import ingest_cache_size, ingest_ladder_bound

OUT = "BENCH_ingest.json"
DELTA = 1e-3  # match stream_throughput: measure machinery, not solve depth
K = 5

# seed phase: mixed stream (mostly-labeled inserts + deletes) growing the
# store through several capacity rungs; measured phase: all-labeled
# insert batches (steady state — no supernode re-init churn, every batch
# still solves the affected frontier)
FULL = dict(dim=256, seed_rows=8000, seed_batch=200,
            meas_batches=30, meas_batch=64)
TINY = dict(dim=128, seed_rows=2000, seed_batch=200,
            meas_batches=10, meas_batch=64)
SEED_LABELED_FRAC = 0.9
SEED_DELETE_FRAC = 0.05  # of each seed batch, from prior alive rows
WARM_STEPS = 2  # measured-shape batches stepped before the clock starts
ROUNDS = 2

# Recorded floors for --check.  The reference is the ROADMAP ingestion
# item's number for the path the device pipeline replaces: "host kNN
# staging caps mutation throughput at ~200 ops/s" (ROADMAP.md §Open
# items, measured on the pre-incremental host selector).  The device
# floor is the PR's acceptance headline — 5x that reference, end to end
# through commit.  The live host arm is gated against the reference
# too: it shares this PR's graph-merge speedups, so it clearing 200
# ops/s keeps the recorded provenance conservative rather than stale.
HOST_STAGING_OPS_PER_SEC = 200.0
DEVICE_OVER_REFERENCE_FLOOR = 5.0


def _make_stream(cfg: dict, seed: int = 0):
    """One deterministic stream, replayed verbatim by both arms.

    Returns (seed_batches, warm_batches, measured_batches); deletes pick
    from rows alive at generation time, so the same ids are valid in
    every replay.
    """
    rng = np.random.default_rng(seed)
    dim = cfg["dim"]

    def insert_batch(m: int, labeled_frac: float) -> BatchUpdate:
        emb = rng.normal(0, 1, (m, dim)).astype(np.float32)
        lab = np.where(rng.random(m) < labeled_frac,
                       rng.integers(0, 2, m), UNLABELED).astype(np.int8)
        return BatchUpdate(emb, lab, np.zeros(0, np.int64))

    next_id = 0
    alive: list[int] = []
    seed_batches = []
    n_del = int(cfg["seed_batch"] * SEED_DELETE_FRAC)
    for _ in range(cfg["seed_rows"] // cfg["seed_batch"]):
        b = insert_batch(cfg["seed_batch"], SEED_LABELED_FRAC)
        dels = np.zeros(0, np.int64)
        if len(alive) > 4 * n_del > 0:
            dels = rng.choice(np.asarray(alive, np.int64), n_del,
                              replace=False)
            gone = set(dels.tolist())
            alive = [i for i in alive if i not in gone]
        seed_batches.append(BatchUpdate(b.ins_emb, b.ins_labels,
                                        np.sort(dels)))
        alive += range(next_id, next_id + cfg["seed_batch"])
        next_id += cfg["seed_batch"]
    warm = [insert_batch(cfg["meas_batch"], 1.0) for _ in range(WARM_STEPS)]
    meas = [insert_batch(cfg["meas_batch"], 1.0)
            for _ in range(cfg["meas_batches"])]
    return seed_batches, warm, meas


def _fingerprint(g: DynamicGraph) -> dict[str, bytes]:
    """Byte images of everything the selector contract promises to keep
    identical: committed labels, per-row adjacency, and the edge list."""
    return {name: np.ascontiguousarray(arr).tobytes()
            for name, arr in (("f", g.f), ("labels", g.labels),
                              ("knn_idx", g.knn_idx), ("knn_wgt", g.knn_wgt),
                              ("src", g.src), ("dst", g.dst),
                              ("wgt", g.wgt))}


def _run_arm(ingest: str, cfg: dict, stream) -> dict:
    seed_batches, warm, meas = stream
    g = DynamicGraph(emb_dim=cfg["dim"], k=K)
    eng = StreamEngine(g, delta=DELTA, ingest=ingest)
    for b in seed_batches:
        eng.step(b)
    for b in warm:
        eng.step(b)
    rows = sum(len(b.ins_emb) for b in meas)
    t0 = time.perf_counter()
    for b in meas:
        eng.step(b)
    dt = time.perf_counter() - t0
    max_k = max(k for _, k in eng.bucket_keys)
    return {
        "ops_per_sec": round(rows / dt, 1),
        "measured_rows": rows,
        "measured_s": round(dt, 4),
        "total_rows": g.num_nodes,
        "alive_rows": int(g.alive.sum()),
        "recompiles": eng.recompile_count,
        "ladder_bound": ladder_size(g.num_nodes + 256, max_k),
        "fingerprint": _fingerprint(g),
    }


def main(out: str = OUT, tiny: bool = False, check: bool = False) -> dict:
    cfg = TINY if tiny else FULL
    stream = _make_stream(cfg)
    max_batch = max(cfg["seed_batch"], cfg["meas_batch"])
    arms = ("host", "device")
    best: dict[str, dict] = {}
    history: dict[str, list] = {a: [] for a in arms}
    for _ in range(ROUNDS):  # interleaved best-of: drift hits both arms
        for arm in arms:
            r = _run_arm(arm, cfg, stream)
            history[arm].append(r["ops_per_sec"])
            if arm not in best or r["ops_per_sec"] > best[arm]["ops_per_sec"]:
                best[arm] = r
    # kernel-vs-oracle agreement, end to end: the device arm's committed
    # graph must be byte-identical to the host oracle's.  Deterministic
    # per arm, so comparing the best rounds compares every round.
    fp_h = best["host"].pop("fingerprint")
    fp_d = best["device"].pop("fingerprint")
    mismatch = [k for k in fp_h if fp_h[k] != fp_d[k]]
    agreement = 0.0 if mismatch else 1.0

    cache = ingest_cache_size()
    cache_bound = ingest_ladder_bound(best["device"]["total_rows"], max_batch)
    best["device"]["ingest_cache_entries"] = cache
    best["device"]["ingest_cache_bound"] = cache_bound

    results = {
        "config": {k: v for k, v in cfg.items()},
        "rounds": ROUNDS,
        "ops_per_sec_per_round": history,
        "floors": {
            "host_staging_ops_per_sec": HOST_STAGING_OPS_PER_SEC,
            "device_over_reference": DEVICE_OVER_REFERENCE_FLOOR,
        },
        "device_over_reference": round(
            best["device"]["ops_per_sec"] / HOST_STAGING_OPS_PER_SEC, 2),
        "device_over_host_live": round(
            best["device"]["ops_per_sec"]
            / max(best["host"]["ops_per_sec"], 1e-9), 3),
        "agreement": agreement,
    }
    results.update(best)
    for arm in arms:
        r = best[arm]
        print(f"{arm}: {r['ops_per_sec']:.0f} ops/s steady "
              f"({r['measured_rows']} rows / {r['measured_s']:.2f} s) | "
              f"{r['total_rows']} rows total | {r['recompiles']} recompiles "
              f"≤ ladder {r['ladder_bound']}")
    print(f"device/reference {results['device_over_reference']}x "
          f"(recorded host staging {HOST_STAGING_OPS_PER_SEC} ops/s) | "
          f"device/host-live {results['device_over_host_live']}x | "
          f"agreement {agreement} | ingest cache {cache} ≤ {cache_bound}")
    if check:
        floor = DEVICE_OVER_REFERENCE_FLOOR * HOST_STAGING_OPS_PER_SEC
        _gate("device/throughput",
              best["device"]["ops_per_sec"] >= floor,
              f"{best['device']['ops_per_sec']} ops/s < "
              f"{DEVICE_OVER_REFERENCE_FLOOR}x recorded host staging "
              f"({floor} ops/s)")
        _gate("host/reference",
              best["host"]["ops_per_sec"] >= HOST_STAGING_OPS_PER_SEC,
              f"live host arm {best['host']['ops_per_sec']} ops/s < the "
              f"recorded {HOST_STAGING_OPS_PER_SEC} ops/s reference it "
              "is supposed to dominate")
        _gate("agreement", agreement == 1.0,
              f"device arm diverged from the host oracle in: {mismatch}")
        for arm in arms:
            _gate(f"{arm}/recompiles",
                  best[arm]["recompiles"] <= best[arm]["ladder_bound"],
                  f"{best[arm]['recompiles']} recompiles > ladder bound "
                  f"{best[arm]['ladder_bound']}")
        _gate("device/ingest_cache", cache <= cache_bound,
              f"{cache} ingest jit entries > ladder bound {cache_bound}")
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {os.path.abspath(out)}")
    if check:
        finish_checks()
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 2000-row seed stream")
    ap.add_argument("--check", action="store_true",
                    help="assert recorded floors + bit-identical arms "
                         "+ compile-once bounds")
    ap.add_argument("--out", default=OUT, help="output JSON path")
    args = ap.parse_args()
    main(out=args.out, tiny=args.tiny, check=args.check)

"""Paper Fig. 6: impact of the update threshold δ on iterations, time and
accuracy (accuracy measured against the harmonic solution, as in the paper:
"relative to the baseline method of Wagner et al., which optimally minimizes
the energy function").

Claims under test: larger δ ⇒ fewer iterations & lower accuracy;
δ = 1e-4 is near-optimal (≈99%); accuracy decreases with batch size (6b).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import harmonic_reference, run_stream, spec_for
from repro.core.dynlp import DynLP
from repro.data.synth import accuracy


def run(n=8_000, deltas=(1e-1, 1e-2, 1e-3, 1e-4, 1e-5)):
    rows = []
    for d in deltas:
        out = run_stream(DynLP, spec_for(n, seed=9, noise=1.1), delta=d)
        ids, f_h = harmonic_reference(out["graph"])
        pred_h = (f_h >= 0.5).astype(np.int8)
        acc_h = accuracy(out["pred"], pred_h)
        rows.append({"delta": d, "iterations": out["total_iters"],
                     "ms": out["total_ms"], "acc_vs_harmonic": acc_h})
    return rows


def run_batch_sweep(n=12_000, batches=(2_000, 6_000, 12_000), delta=1e-4):
    rows = []
    for b in batches:
        out = run_stream(DynLP, spec_for(n, batch=b, seed=11, noise=1.1),
                         delta=delta)
        ids, f_h = harmonic_reference(out["graph"])
        acc_h = accuracy(out["pred"], (f_h >= 0.5).astype(np.int8))
        rows.append({"batch": b, "acc_vs_harmonic": acc_h,
                     "iterations": out["total_iters"]})
    return rows


def main(full: bool = False):
    rows = run(8_000 if full else 3_000)
    print("fig6a: delta,iterations,ms,acc_vs_harmonic")
    for r in rows:
        print(f"fig6a,{r['delta']},{r['iterations']},{r['ms']:.0f},"
              f"{r['acc_vs_harmonic']:.4f}")
    iters = [r["iterations"] for r in rows]
    assert iters[0] <= iters[-1], iters  # larger δ terminates earlier
    assert rows[-2]["acc_vs_harmonic"] >= 0.98  # δ=1e-4 near-optimal
    rows_b = run_batch_sweep(12_000 if full else 4_000,
                             (2_000, 6_000, 12_000) if full else (1_000, 2_000, 4_000))
    print("fig6b: batch,iterations,acc_vs_harmonic")
    for r in rows_b:
        print(f"fig6b,{r['batch']},{r['iterations']},{r['acc_vs_harmonic']:.4f}")
    return rows, rows_b


if __name__ == "__main__":
    main()

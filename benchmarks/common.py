"""Shared benchmark utilities: stream runners, timing, --check gates."""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.dynlp import DynLP
from repro.core.itlp import ITLP
from repro.core.snapshot import build_problem
from repro.core.stlp import STLP
from repro.data.synth import StreamSpec, accuracy, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, DynamicGraph


# ------------------------------------------------------------------ #
# --check gate harness (shared by stream_throughput.py / serve_lp.py):
# a violated recorded floor is collected as a one-line diff instead of
# raising, so EVERY regression prints before the nonzero exit.
# ------------------------------------------------------------------ #
_CHECK_FAILURES: list[str] = []


def check_gate(name: str, ok: bool, detail: str) -> None:
    """Record a --check floor violation (reported by ``finish_checks``)."""
    if not ok:
        _CHECK_FAILURES.append(f"{name}: {detail}")


def finish_checks() -> None:
    """Print collected one-line diffs and exit nonzero if any floor was
    violated; clears the collection either way (run.py may drive several
    benchmarks in one process)."""
    failures, _CHECK_FAILURES[:] = list(_CHECK_FAILURES), []
    if failures:
        for line in failures:
            print("CHECK FAIL", line)
        sys.exit(1)


def run_stream(engine_cls, spec: StreamSpec, k: int = 5, **engine_kw):
    """Run a full stream; returns (graph, per-batch stats, truth map)."""
    g = DynamicGraph(emb_dim=spec.emb_dim, k=k)
    eng = engine_cls(g, **engine_kw)
    truth = {}
    stats = []
    for batch, cls in gaussian_mixture_stream(spec):
        base = g.num_nodes
        stats.append(eng.step(batch))
        for i, c in enumerate(cls):
            truth[base + i] = c
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    pred = (g.f[ids] >= 0.5).astype(np.int8)
    tr = np.array([truth[i] for i in ids]) if len(ids) else np.zeros(0, np.int8)
    return {
        "graph": g, "engine": eng, "stats": stats, "ids": ids, "pred": pred,
        "truth": tr,
        "acc_vs_truth": accuracy(pred, tr),
        "total_ms": sum(s.wall_ms for s in stats),
        "total_iters": sum(getattr(s, "iterations", 0) for s in stats),
    }


def harmonic_reference(g, delta=1e-7):
    """Binary labels of the near-exact harmonic solution (iterated)."""
    import jax.numpy as jnp

    from repro.core.propagate import propagate_full

    snap = build_problem(g)
    u = len(snap.unl_ids)
    res = propagate_full(snap.problem, jnp.full((snap.problem.num_unlabeled,), 0.5),
                         delta=delta, max_iters=500_000)
    f = np.asarray(res.f)[:u]
    return snap.unl_ids, f


def spec_for(n: int, batch: int | None = None, seed: int = 0,
             sep: float = 6.0, noise: float = 0.9) -> StreamSpec:
    return StreamSpec(total_vertices=n, batch_size=batch or n, seed=seed,
                      class_sep=sep, noise=noise)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.ms = (time.perf_counter() - self.t0) * 1e3

"""Paper Table 4: single-batch comparison across methods at growing batch
sizes (T = execution time, A = accuracy vs the exact harmonic labels).

Claims under test: DynLP fastest at every size with ~optimal accuracy;
STLP exact but slow / memory-capped; STLP(γ) scales further but loses
accuracy monotonically in γ (Table 4's 72.9 / 83.5 / 56.3 pattern).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import harmonic_reference, run_stream, spec_for
from repro.core.dynlp import DynLP
from repro.core.itlp import ITLP
from repro.core.stlp import STLP
from repro.data.synth import accuracy


def run(sizes=(1_000, 4_000), stlp_cap=6_000):
    rows = []
    for n in sizes:
        spec = spec_for(n, seed=23, noise=1.1)
        methods = {
            "ITLP": lambda: run_stream(ITLP, spec, delta=1e-4),
            "DynLP": lambda: run_stream(DynLP, spec, delta=1e-4),
        }
        if n <= stlp_cap:
            methods["STLP"] = lambda: run_stream(STLP, spec)
            methods["STLP(g=1)"] = lambda: run_stream(STLP, spec, gamma=1.0)
            methods["STLP(g=10)"] = lambda: run_stream(STLP, spec, gamma=10.0)
        ref = None
        for name, fn in methods.items():
            out = fn()
            if ref is None:
                ids, f_h = harmonic_reference(out["graph"])
                ref = (f_h >= 0.5).astype(np.int8)
            rows.append({
                "n": n, "method": name, "ms": out["total_ms"],
                "acc_vs_harmonic": accuracy(out["pred"], ref),
            })
    return rows


def main(full: bool = False):
    rows = run((1_000, 4_000, 12_000) if full else (1_000, 3_000))
    print("table4: n,method,ms,acc_vs_harmonic")
    for r in rows:
        print(f"table4,{r['n']},{r['method']},{r['ms']:.0f},"
              f"{r['acc_vs_harmonic']:.4f}")
    by = {(r["n"], r["method"]): r for r in rows}
    ns = sorted({r["n"] for r in rows})
    for n in ns:
        if (n, "STLP(g=1)") in by:
            assert (by[(n, "STLP(g=1)")]["acc_vs_harmonic"] + 0.02
                    >= by[(n, "STLP(g=10)")]["acc_vs_harmonic"]), n
        assert by[(n, "DynLP")]["acc_vs_harmonic"] >= 0.97, n
    return rows


if __name__ == "__main__":
    main()

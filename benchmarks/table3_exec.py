"""Paper Table 3: execution-time comparison across datasets.

The paper's datasets (IMDB 50K .. Amazon Book 29.5M reviews) are embedding
streams -> cosine kNN graphs; offline we synthesize matched-shape surrogates
(two-class Gaussian embedding mixtures at several scales, k=5) and compare
ITLP / STLP / DynLP on one batch with 1% ground truth — the paper's own
protocol for this table.  Claim: DynLP fastest everywhere and the gap grows
with graph size; STLP only fits the smallest dataset.
"""

from __future__ import annotations

from benchmarks.common import run_stream, spec_for
from repro.core.dynlp import DynLP
from repro.core.itlp import ITLP
from repro.core.stlp import STLP

DATASETS = {  # name -> vertices (scaled-down surrogates of Table 2)
    "imdb-like": 5_000,
    "yelp-like": 20_000,
    "household-like": 40_000,
}


def run(datasets=None, stlp_cap=8_000):
    datasets = datasets or DATASETS
    rows = []
    for name, n in datasets.items():
        spec = spec_for(n, seed=29)
        itl = run_stream(ITLP, spec, delta=1e-4)
        dyn = run_stream(DynLP, spec, delta=1e-4)
        row = {"dataset": name, "n": n, "itlp_ms": itl["total_ms"],
               "dynlp_ms": dyn["total_ms"],
               "speedup": itl["total_ms"] / max(dyn["total_ms"], 1e-9)}
        if n <= stlp_cap:
            stl = run_stream(STLP, spec)
            row["stlp_ms"] = stl["total_ms"]
        rows.append(row)
    return rows


def main(full: bool = False):
    ds = DATASETS if full else {"imdb-like": 4_000, "yelp-like": 10_000}
    rows = run(ds)
    print("table3: dataset,n,itlp_ms,stlp_ms,dynlp_ms,speedup")
    for r in rows:
        print(f"table3,{r['dataset']},{r['n']},{r['itlp_ms']:.0f},"
              f"{r.get('stlp_ms', float('nan')):.0f},{r['dynlp_ms']:.0f},"
              f"{r['speedup']:.2f}")
    return rows


if __name__ == "__main__":
    main()

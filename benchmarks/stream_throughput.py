"""Compile-once streaming throughput vs. the rebuild-every-batch baseline.

Drives ``examples/dynamic_stream.py``-style synthetic streams (50+ batches,
two insert/delete mixes) through

  * ``StreamEngine``      — bucket-ladder shapes + persistent donated
                            buffers + pipelined submit/drain,
  * naive ``DynLP``       — ``auto_bucket=False``: the device problem is
                            rebuilt at its exact (U, K) every Δ_t, so the
                            propagation jit recompiles on nearly every
                            batch (the paper's "redundant recomputation"
                            tax, restated from PAPER.md), and
  * bucketed ``DynLP``    — ``auto_bucket=True``, the pre-StreamEngine
                            default (row buckets + multiple-of-8 K), kept
                            honest as a third arm so the headline is not
                            only measured against the worst case.

When more than one device is visible a fourth arm runs: ``StreamEngine``
with a flat mesh over every local device (the ``core.distributed``
all-gather transport) — sharded vs single-device per-batch wall ms on the
same stream.  Set ``REPRO_FORCE_HOST_DEVICES=8`` to force an 8-virtual-
device CPU mesh (must be decided before jax initializes, hence the env
hook below); the CI benchmark-smoke job does exactly this.

Per config it records recompile counts, per-batch wall ms, and batches/sec
into ``BENCH_stream.json`` (repo root / cwd).  Acceptance target: median
per-batch speedup ≥ 3x vs the naive rebuild on CPU with streamed
recompiles ≤ the bucket-ladder size (``--check`` turns the bound into a
hard assert; ``--tiny`` shrinks the streams for CI smoke runs).
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

# Must run before jax initializes: virtual CPU devices for the sharded arm.
_force = os.environ.get("REPRO_FORCE_HOST_DEVICES")
if _force:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_force}"
    ).strip()

import jax
import numpy as np

from repro.core.dynlp import DynLP
from repro.core.snapshot import bucket_k, ladder_size
from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, accuracy, gaussian_mixture_stream, hub_stream
from repro.graph.dynamic import DynamicGraph
from repro.kernels import ops
from repro.launch.mesh import make_stream_mesh

OUT = "BENCH_stream.json"

# Truncated-vs-untruncated prediction agreement the max_k arm must hold
# (same floor as tests/test_max_k_accuracy.py's slow-tier assert).
MAX_K_AGREEMENT_FLOOR = 0.98

# All three arms converge to the same labels at the same δ; a looser δ
# keeps the measurement on the update machinery (rebuild/compile/staging
# cost per Δ_t) instead of convergence depth, which is identical work in
# every arm and only compresses the ratios into the noise floor.
DELTA = 1e-3

CONFIGS = {
    # 50-batch insert-heavy stream (paper's 90/1/9 protocol)
    "ins_heavy_50": dict(total_vertices=3000, batch_size=60, seed=0,
                         class_sep=6.0, noise=0.9, frac_deleted=0.09),
    # high-churn mix: every Δ_t deletes a quarter batch
    "churn_50": dict(total_vertices=3000, batch_size=60, seed=1,
                     class_sep=6.0, noise=0.9, frac_deleted=0.25,
                     frac_unlabeled=0.74),
}


def _run_streamed(spec: StreamSpec, mesh=None) -> dict:
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=DELTA, mesh=mesh)
    stats = []
    marks = [time.perf_counter()]
    for batch, _ in gaussian_mixture_stream(spec):
        prev = eng.submit(batch)  # pipelined: stage t while t-1 propagates
        marks.append(time.perf_counter())
        if prev is not None:
            stats.append(prev)
    stats.append(eng.drain())
    marks.append(time.perf_counter())
    # Pipelined batches overlap, so per-batch cost is the wall time between
    # submit boundaries (StreamStats.wall_ms would double-count the next
    # batch's host work that runs while this one drains).
    per_batch_ms = [(b - a) * 1e3 for a, b in zip(marks, marks[1:])]
    final_drain = per_batch_ms.pop()  # fold the final drain into batch N
    per_batch_ms[-1] += final_drain
    max_k = max(k for _, k in eng.bucket_keys)
    out = {
        "per_batch_ms": [round(ms, 3) for ms in per_batch_ms],
        "median_ms": statistics.median(per_batch_ms),
        "total_s": sum(per_batch_ms) / 1e3,
        "batches": eng.batches,
        "batches_per_sec": eng.batches / (sum(per_batch_ms) / 1e3),
        "recompiles": eng.recompile_count,
        "bucket_keys": sorted(eng.bucket_keys),
        "ladder_bound": ladder_size(spec.total_vertices + 256, max_k),
        "iterations": sum(s.iterations for s in stats),
    }
    if mesh is not None:
        out["mesh_devices"] = int(mesh.devices.size)
        out["plan_builds"] = eng.plan_builds
    return out


def _run_dynlp(spec: StreamSpec, auto_bucket: bool) -> dict:
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    dyn = DynLP(g, delta=DELTA, auto_bucket=auto_bucket)
    cache0 = ops.compile_cache_size()
    per_batch_ms = []
    iters = 0
    for batch, _ in gaussian_mixture_stream(spec):
        st = dyn.step(batch)
        per_batch_ms.append(st.wall_ms)
        iters += st.iterations
    return {
        "per_batch_ms": [round(ms, 3) for ms in per_batch_ms],
        "median_ms": statistics.median(per_batch_ms),
        "total_s": sum(per_batch_ms) / 1e3,
        "batches": len(per_batch_ms),
        "batches_per_sec": len(per_batch_ms) / (sum(per_batch_ms) / 1e3),
        "recompiles": ops.compile_cache_size() - cache0,
        "iterations": iters,
    }


def _run_max_k_accuracy(cap: int = 8, n_batches: int = 5, per_hub: int = 20,
                        hubs: int = 4, seed: int = 0) -> dict:
    """max_k accuracy arm (ROADMAP follow-up): stream a hub-heavy graph
    with and without the heaviest-edge K cap and measure how far the
    truncated labels drift from the untruncated ones (plus both arms'
    accuracy against ground truth and the K-ladder shrinkage the cap
    buys)."""

    def run(max_k):
        g = DynamicGraph(emb_dim=8, k=4)
        eng = StreamEngine(g, delta=DELTA, max_k=max_k)
        truth = []
        for batch, cls in hub_stream(n_batches=n_batches, per_hub=per_hub,
                                     hubs=hubs, seed=seed):
            eng.step(batch)
            truth.extend(int(c) for c in cls)
        return g, eng, np.asarray(truth, np.int8)

    g_free, eng_free, truth = run(None)
    g_cap, eng_cap, _ = run(cap)
    # both arms saw the identical insert-only stream, so the id sets match
    ids, pred_free = eng_free.predictions()
    _, pred_cap = eng_cap.predictions()
    return {
        "max_k": cap,
        "agreement": round(float((pred_free == pred_cap).mean()), 4),
        "accuracy_untruncated": round(accuracy(pred_free, truth[ids]), 4),
        "accuracy_truncated": round(accuracy(pred_cap, truth[ids]), 4),
        "natural_max_K": max(k for _, k in eng_free.bucket_keys),
        "capped_max_K": max(k for _, k in eng_cap.bucket_keys),
        "rungs_untruncated": len(eng_free.bucket_keys),
        "rungs_truncated": len(eng_cap.bucket_keys),
        "agreement_floor": MAX_K_AGREEMENT_FLOOR,
    }


def main(full: bool = False, out: str = OUT, tiny: bool = False,
         check: bool = False) -> dict:
    n_dev = len(jax.devices())
    mesh = make_stream_mesh() if n_dev > 1 else None
    results = {
        "backend_auto_resolves_to": ops.select_backend("auto"),
        "devices": n_dev,
        "sharded_arm": mesh is not None,
    }
    for name, kw in CONFIGS.items():
        if full:
            kw = dict(kw, total_vertices=kw["total_vertices"] * 2)
        if tiny:  # CI smoke: a few rungs, seconds not minutes
            kw = dict(kw, total_vertices=600, batch_size=60)
        spec = StreamSpec(**kw)
        naive = _run_dynlp(spec, auto_bucket=False)
        bucketed = _run_dynlp(spec, auto_bucket=True)
        streamed = _run_streamed(spec)
        speedup = naive["median_ms"] / streamed["median_ms"]
        speedup_b = bucketed["median_ms"] / streamed["median_ms"]
        results[name] = {
            "stream": streamed,
            "naive_rebuild": naive,
            "dynlp_bucketed": bucketed,
            "median_per_batch_speedup": round(speedup, 2),
            "median_speedup_vs_bucketed_dynlp": round(speedup_b, 2),
        }
        print(f"{name}: {streamed['batches']} batches | "
              f"stream {streamed['median_ms']:.1f} ms/batch "
              f"({streamed['batches_per_sec']:.1f} batches/s, "
              f"{streamed['recompiles']} recompiles ≤ ladder "
              f"{streamed['ladder_bound']}) | naive "
              f"{naive['median_ms']:.1f} ms/batch "
              f"({naive['recompiles']} recompiles) | "
              f"median speedup {speedup:.1f}x vs naive, "
              f"{speedup_b:.1f}x vs bucketed DynLP "
              f"({bucketed['recompiles']} recompiles)")
        arms = {"stream": streamed}
        if mesh is not None:
            sharded = _run_streamed(spec, mesh=mesh)
            results[name]["stream_sharded"] = sharded
            results[name]["sharded_vs_single_device_median_ms"] = [
                round(sharded["median_ms"], 3), round(streamed["median_ms"], 3)]
            arms["stream_sharded"] = sharded
            print(f"{name}: sharded({sharded['mesh_devices']} dev) "
                  f"{sharded['median_ms']:.1f} ms/batch vs single-device "
                  f"{streamed['median_ms']:.1f} ms/batch | "
                  f"{sharded['plan_builds']} plans for "
                  f"{len(sharded['bucket_keys'])} rungs, "
                  f"{sharded['recompiles']} recompiles")
        if check:  # the compile-once contract, as a hard gate
            for arm, r in arms.items():
                assert r["recompiles"] <= r["ladder_bound"], (
                    name, arm, r["recompiles"], r["ladder_bound"])
            if mesh is not None:
                assert sharded["plan_builds"] <= len(sharded["bucket_keys"]), (
                    name, sharded["plan_builds"], sharded["bucket_keys"])
    mk = _run_max_k_accuracy(
        n_batches=3 if tiny else 5, per_hub=12 if tiny else 20)
    results["max_k_accuracy"] = mk
    print(f"max_k_accuracy: K {mk['natural_max_K']} -> {mk['capped_max_K']} "
          f"({mk['rungs_untruncated']} -> {mk['rungs_truncated']} rungs) | "
          f"agreement {mk['agreement']:.3f} (floor {mk['agreement_floor']}) | "
          f"accuracy {mk['accuracy_untruncated']:.3f} untruncated / "
          f"{mk['accuracy_truncated']:.3f} truncated")
    if check:
        assert mk["agreement"] >= MAX_K_AGREEMENT_FLOOR, mk
        # bucket_keys hold the LADDER-padded K, so compare on the rung
        assert mk["capped_max_K"] <= bucket_k(mk["max_k"]), mk
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {os.path.abspath(out)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="2x vertices per config")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 600-vertex streams")
    ap.add_argument("--check", action="store_true",
                    help="assert recompiles <= bucket-ladder bound "
                         "(and plan reuse on the sharded arm)")
    ap.add_argument("--out", default=OUT, help="output JSON path")
    args = ap.parse_args()
    main(full=args.full, out=args.out, tiny=args.tiny, check=args.check)

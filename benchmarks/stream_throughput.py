"""Compile-once streaming throughput vs. the rebuild-every-batch baseline.

Drives ``examples/dynamic_stream.py``-style synthetic streams (50+ batches,
two insert/delete mixes) through

  * ``StreamEngine``      — bucket-ladder shapes + persistent donated
                            buffers + pipelined submit/drain,
  * naive ``DynLP``       — ``auto_bucket=False``: the device problem is
                            rebuilt at its exact (U, K) every Δ_t, so the
                            propagation jit recompiles on nearly every
                            batch (the paper's "redundant recomputation"
                            tax, restated from PAPER.md), and
  * bucketed ``DynLP``    — ``auto_bucket=True``, the pre-StreamEngine
                            default (row buckets + multiple-of-8 K), kept
                            honest as a third arm so the headline is not
                            only measured against the worst case.

When more than one device is visible two more arms run: ``StreamEngine``
with a flat mesh over every local device (sharded vs single-device
per-batch wall ms on the same stream), and the TRANSPORT arm — the same
mesh-sharded engine on a locality-ordered stream
(``data.synth.locality_stream``) under ``transport="allgather"`` vs
``transport="halo"``, recording steady-state per-batch medians, per-rung
export budgets/fractions, overflow fallbacks, and byte-identical labels.
Set ``REPRO_FORCE_HOST_DEVICES=8`` to force an 8-virtual-device CPU mesh
(must be decided before jax initializes, hence the env hook below); the
CI benchmark-smoke job does exactly this.

A BACKEND arm drives the same stream through every backend in the
``kernels.ops`` registry (``ref`` / ``ell_pallas`` / ``bsr``, Pallas
backends in interpret mode on CPU), recording per-batch medians,
compile counts vs the ladder bound, the per-rung registry decisions
(rung backends, BSR slot budgets, overflow fallbacks) and each
backend's max |Δf| against the ref arm.

Per config it records recompile counts, per-batch wall ms, and batches/sec
into ``BENCH_stream.json`` (repo root / cwd).  ``--check`` gates the
recorded floors — compile-once bounds, the naive-rebuild speedup floor,
max_k agreement, the transport contract (byte-identical labels, halo
plan_builds ≤ rungs, zero overflows, steady-median ratio and export
fraction under their recorded ceilings), and the backend contract
(labels within the recorded |Δf| floor of ref, compiles ≤ ladder + slot
overflows, every bsr batch actually solved on bsr) — and exits nonzero
with a one-line diff per violated floor.  ``--tiny`` shrinks the
streams for CI smoke runs.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

# Must run before jax initializes: virtual CPU devices for the sharded arm.
_force = os.environ.get("REPRO_FORCE_HOST_DEVICES")
if _force:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_force}"
    ).strip()

import jax
import numpy as np

try:
    from benchmarks.common import check_gate as _gate, finish_checks
except ImportError:  # run as a script: sys.path[0] is benchmarks/ itself
    from common import check_gate as _gate, finish_checks

from repro.core.dynlp import DynLP
from repro.core.snapshot import bucket_k, ladder_size
from repro.core.stream import StreamEngine
from repro.data.synth import (StreamSpec, accuracy, gaussian_mixture_stream,
                              hub_stream, locality_stream)
from repro.graph.dynamic import DynamicGraph
from repro.kernels import ops
from repro.launch.mesh import make_stream_mesh

OUT = "BENCH_stream.json"

# Truncated-vs-untruncated prediction agreement the max_k arm must hold
# (same floor as tests/test_max_k_accuracy.py's slow-tier assert).
MAX_K_AGREEMENT_FLOOR = 0.98

# Recorded floors for --check: a regression exits nonzero with a
# one-line diff per violated floor (not just a structural assert).
SPEEDUP_FLOOR = 2.0  # median per-batch speedup vs the naive rebuild
# Transport arm (locality-ordered stream): halo steady-state per-batch
# median may exceed all-gather by at most this factor (CPU collectives
# are shared-memory copies, so the byte savings land mostly in the
# recorded export fractions; the ratio floor guards against the halo
# path regressing into real overhead).
TRANSPORT_STEADY_RATIO_MAX = 1.25
# ...and the top rung's export fraction must show the bytes actually
# shrink: budget*D/U_pad of the largest rung the stream touched.
TRANSPORT_TOP_RUNG_FRACTION_MAX = 0.5

# All three arms converge to the same labels at the same δ; a looser δ
# keeps the measurement on the update machinery (rebuild/compile/staging
# cost per Δ_t) instead of convergence depth, which is identical work in
# every arm and only compresses the ratios into the noise floor.
DELTA = 1e-3

CONFIGS = {
    # 50-batch insert-heavy stream (paper's 90/1/9 protocol)
    "ins_heavy_50": dict(total_vertices=3000, batch_size=60, seed=0,
                         class_sep=6.0, noise=0.9, frac_deleted=0.09),
    # high-churn mix: every Δ_t deletes a quarter batch
    "churn_50": dict(total_vertices=3000, batch_size=60, seed=1,
                     class_sep=6.0, noise=0.9, frac_deleted=0.25,
                     frac_unlabeled=0.74),
}


def _run_streamed(spec: StreamSpec, mesh=None) -> dict:
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=DELTA, mesh=mesh)
    stats = []
    marks = [time.perf_counter()]
    for batch, _ in gaussian_mixture_stream(spec):
        prev = eng.submit(batch)  # pipelined: stage t while t-1 propagates
        marks.append(time.perf_counter())
        if prev is not None:
            stats.append(prev)
    stats.append(eng.drain())
    marks.append(time.perf_counter())
    # Pipelined batches overlap, so per-batch cost is the wall time between
    # submit boundaries (StreamStats.wall_ms would double-count the next
    # batch's host work that runs while this one drains).
    per_batch_ms = [(b - a) * 1e3 for a, b in zip(marks, marks[1:])]
    final_drain = per_batch_ms.pop()  # fold the final drain into batch N
    per_batch_ms[-1] += final_drain
    max_k = max(k for _, k in eng.bucket_keys)
    out = {
        "per_batch_ms": [round(ms, 3) for ms in per_batch_ms],
        "median_ms": statistics.median(per_batch_ms),
        "total_s": sum(per_batch_ms) / 1e3,
        "batches": eng.batches,
        "batches_per_sec": eng.batches / (sum(per_batch_ms) / 1e3),
        "recompiles": eng.recompile_count,
        "bucket_keys": sorted(eng.bucket_keys),
        "ladder_bound": ladder_size(spec.total_vertices + 256, max_k),
        "iterations": sum(s.iterations for s in stats),
    }
    if mesh is not None:
        out["mesh_devices"] = int(mesh.devices.size)
        out["plan_builds"] = eng.plan_builds
        out["transport"] = eng.transport_summary()
    return out


# Backend arm: the same stream through every registered backend (Pallas
# ones in interpret mode on CPU).  The recorded floors are correctness
# (labels within BACKEND_MAX_ABS_DIFF of ref), the compile-once bound
# (+1 per recorded slot-budget overflow), and zero overflows on this
# deterministic stream.
BACKEND_CONFIG = dict(total_vertices=500, batch_size=100, seed=6,
                      class_sep=6.0, noise=0.9, frac_deleted=0.1,
                      frac_unlabeled=0.89)
# bsr sums edges in tile order, so per-row updates near the δ threshold
# stop a few δ apart from ref; 20·δ is the same calibration the test
# suite uses (atol 2e-3 at δ=1e-4, tests/test_stream_bsr.py).
BACKEND_MAX_ABS_DIFF = 20 * DELTA


def _run_backend_arm(tiny: bool = False) -> dict:
    """One stream per registry backend — per-batch medians, recompiles
    vs the ladder bound, per-rung registry decisions (rung_backends,
    slot budgets, overflow fallbacks) and max |Δf| vs the ref arm."""
    kw = dict(BACKEND_CONFIG)
    if tiny:
        kw.update(total_vertices=240, batch_size=60)
    spec = StreamSpec(**kw)
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    out: dict = {"spec": kw, "batches": len(batches),
                 "backends": list(ops.backend_names())}
    labels = {}
    for backend in ops.backend_names():
        g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
        eng = StreamEngine(g, delta=DELTA, backend=backend, block_rows=128)
        cache0 = ops.compile_cache_size()
        stats = []
        marks = [time.perf_counter()]
        for b in batches:
            stats.append(eng.step(b))
            marks.append(time.perf_counter())
        per_batch = [(b - a) * 1e3 for a, b in zip(marks, marks[1:])]
        steady = [ms for ms, s in zip(per_batch, stats) if not s.recompiled]
        max_k = max(k for _, k in eng.bucket_keys)
        summary = eng.transport_summary()
        labels[backend] = g.f.copy()
        out[backend] = {
            "median_ms": round(statistics.median(per_batch), 3),
            "steady_median_ms": round(statistics.median(steady), 3)
            if steady else None,
            "recompiles": ops.compile_cache_size() - cache0,
            "ladder_bound": ladder_size(spec.total_vertices + 256, max_k),
            "rungs": len(eng.bucket_keys),
            "rung_backends": summary["rung_backends"],
            "bsr_batches": summary["bsr_batches"],
            "backend_overflows": summary["backend_overflows"],
            "slot_budgets": summary["slot_budgets"],
        }
        if backend != "ref":
            out[backend]["max_abs_diff_vs_ref"] = round(
                float(np.abs(labels[backend] - labels["ref"]).max()), 6)
    out["floors"] = {"max_abs_diff_vs_ref": BACKEND_MAX_ABS_DIFF}
    return out


TRANSPORT_CONFIG = dict(total_vertices=3000, batch_size=150, seed=3,
                        emb_dim=2, class_sep=6.0, noise=0.9,
                        frac_deleted=0.1, frac_unlabeled=0.89)


def _run_transport_arm(mesh, tiny: bool = False) -> dict:
    """allgather-vs-halo on a locality-ordered stream (the workload halo
    exists for: cosine-local arrival order, so export sets are a few
    rows per shard and the per-sweep collective ships a fraction of F).

    Per transport it records all-batch and steady-state (non-recompile)
    per-batch medians, the per-rung export budgets/fractions, overflow
    fallbacks, and plan builds; the headline is the steady median ratio
    plus byte-identical labels across transports.
    """
    kw = dict(TRANSPORT_CONFIG)
    if tiny:
        kw.update(total_vertices=1500, batch_size=100)
    spec = StreamSpec(**kw)
    batches = [b for b, _ in locality_stream(spec)]
    out: dict = {"spec": {k: v for k, v in kw.items()},
                 "batches": len(batches)}

    def drive(transport):
        g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
        eng = StreamEngine(g, delta=DELTA, mesh=mesh, transport=transport)
        stats = []
        marks = [time.perf_counter()]
        for b in batches:
            stats.append(eng.step(b))
            marks.append(time.perf_counter())
        per_batch = [(b - a) * 1e3 for a, b in zip(marks, marks[1:])]
        steady = [ms for ms, s in zip(per_batch, stats) if not s.recompiled]
        summary = eng.transport_summary()
        fractions = {
            rung: round(budget * mesh.devices.size / int(rung.split("x")[0]),
                        4)
            for rung, budget in summary["export_budgets"].items()
        }
        return g.f.copy(), {
            "median_ms": round(statistics.median(per_batch), 3),
            "steady_median_ms": round(statistics.median(steady), 3)
            if steady else None,
            "steady_batches": len(steady),
            "recompiles": eng.recompile_count,
            "plan_builds": eng.plan_builds,
            "rungs": len(eng.bucket_keys),
            "halo_batches": summary["halo_batches"],
            "overflows": summary["overflows"],
            "export_budgets": summary["export_budgets"],
            "export_fraction_by_rung": fractions,
        }

    # Two interleaved rounds per transport; the timing headline is the
    # BEST steady median of the two.  Round 1 pays each transport's
    # compiles; round 2 reuses the memoized plans/runners, so at least
    # one round per arm measures pure steady state — and min-of-medians
    # filters the machine-load drift that biases whichever arm happens
    # to run while a CI runner neighbor is busy.
    labels = {}
    for transport in ("allgather", "halo", "allgather", "halo"):
        f, metrics = drive(transport)
        best = out.get(transport)
        if (best is None or (metrics["steady_median_ms"] or 1e18)
                < (best["steady_median_ms"] or 1e18)):
            out[transport] = metrics
        if transport in labels:
            assert np.array_equal(labels[transport], f)  # determinism
        labels[transport] = f
    out["labels_identical"] = bool(
        np.array_equal(labels["halo"], labels["allgather"]))
    ag, ha = out["allgather"], out["halo"]
    if ag["steady_median_ms"] and ha["steady_median_ms"]:
        out["steady_median_ratio_halo_vs_allgather"] = round(
            ha["steady_median_ms"] / ag["steady_median_ms"], 3)
    if ha["export_fraction_by_rung"]:
        top_rung = max(ha["export_fraction_by_rung"],
                       key=lambda s: int(s.split("x")[0]))
        out["top_rung_export_fraction"] = ha["export_fraction_by_rung"][top_rung]
        out["top_rung"] = top_rung
    out["floors"] = {
        "steady_median_ratio_max": TRANSPORT_STEADY_RATIO_MAX,
        "top_rung_export_fraction_max": TRANSPORT_TOP_RUNG_FRACTION_MAX,
    }
    return out


def _run_dynlp(spec: StreamSpec, auto_bucket: bool) -> dict:
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    dyn = DynLP(g, delta=DELTA, auto_bucket=auto_bucket)
    cache0 = ops.compile_cache_size()
    per_batch_ms = []
    iters = 0
    for batch, _ in gaussian_mixture_stream(spec):
        st = dyn.step(batch)
        per_batch_ms.append(st.wall_ms)
        iters += st.iterations
    return {
        "per_batch_ms": [round(ms, 3) for ms in per_batch_ms],
        "median_ms": statistics.median(per_batch_ms),
        "total_s": sum(per_batch_ms) / 1e3,
        "batches": len(per_batch_ms),
        "batches_per_sec": len(per_batch_ms) / (sum(per_batch_ms) / 1e3),
        "recompiles": ops.compile_cache_size() - cache0,
        "iterations": iters,
    }


def _run_max_k_accuracy(cap: int = 8, n_batches: int = 5, per_hub: int = 20,
                        hubs: int = 4, seed: int = 0) -> dict:
    """max_k accuracy arm (ROADMAP follow-up): stream a hub-heavy graph
    with and without the heaviest-edge K cap and measure how far the
    truncated labels drift from the untruncated ones (plus both arms'
    accuracy against ground truth and the K-ladder shrinkage the cap
    buys)."""

    def run(max_k):
        g = DynamicGraph(emb_dim=8, k=4)
        eng = StreamEngine(g, delta=DELTA, max_k=max_k)
        truth = []
        for batch, cls in hub_stream(n_batches=n_batches, per_hub=per_hub,
                                     hubs=hubs, seed=seed):
            eng.step(batch)
            truth.extend(int(c) for c in cls)
        return g, eng, np.asarray(truth, np.int8)

    g_free, eng_free, truth = run(None)
    g_cap, eng_cap, _ = run(cap)
    # both arms saw the identical insert-only stream, so the id sets match
    ids, pred_free = eng_free.predictions()
    _, pred_cap = eng_cap.predictions()
    return {
        "max_k": cap,
        "agreement": round(float((pred_free == pred_cap).mean()), 4),
        "accuracy_untruncated": round(accuracy(pred_free, truth[ids]), 4),
        "accuracy_truncated": round(accuracy(pred_cap, truth[ids]), 4),
        "natural_max_K": max(k for _, k in eng_free.bucket_keys),
        "capped_max_K": max(k for _, k in eng_cap.bucket_keys),
        "rungs_untruncated": len(eng_free.bucket_keys),
        "rungs_truncated": len(eng_cap.bucket_keys),
        "agreement_floor": MAX_K_AGREEMENT_FLOOR,
    }


def main(full: bool = False, out: str = OUT, tiny: bool = False,
         check: bool = False) -> dict:
    n_dev = len(jax.devices())
    mesh = make_stream_mesh() if n_dev > 1 else None
    results = {
        "backend_auto_resolves_to": ops.select_backend("auto"),
        "devices": n_dev,
        "sharded_arm": mesh is not None,
    }
    for name, kw in CONFIGS.items():
        if full:
            kw = dict(kw, total_vertices=kw["total_vertices"] * 2)
        if tiny:  # CI smoke: a few rungs, seconds not minutes
            kw = dict(kw, total_vertices=600, batch_size=60)
        spec = StreamSpec(**kw)
        naive = _run_dynlp(spec, auto_bucket=False)
        bucketed = _run_dynlp(spec, auto_bucket=True)
        streamed = _run_streamed(spec)
        speedup = naive["median_ms"] / streamed["median_ms"]
        speedup_b = bucketed["median_ms"] / streamed["median_ms"]
        results[name] = {
            "stream": streamed,
            "naive_rebuild": naive,
            "dynlp_bucketed": bucketed,
            "median_per_batch_speedup": round(speedup, 2),
            "median_speedup_vs_bucketed_dynlp": round(speedup_b, 2),
        }
        print(f"{name}: {streamed['batches']} batches | "
              f"stream {streamed['median_ms']:.1f} ms/batch "
              f"({streamed['batches_per_sec']:.1f} batches/s, "
              f"{streamed['recompiles']} recompiles ≤ ladder "
              f"{streamed['ladder_bound']}) | naive "
              f"{naive['median_ms']:.1f} ms/batch "
              f"({naive['recompiles']} recompiles) | "
              f"median speedup {speedup:.1f}x vs naive, "
              f"{speedup_b:.1f}x vs bucketed DynLP "
              f"({bucketed['recompiles']} recompiles)")
        arms = {"stream": streamed}
        if mesh is not None:
            sharded = _run_streamed(spec, mesh=mesh)
            results[name]["stream_sharded"] = sharded
            results[name]["sharded_vs_single_device_median_ms"] = [
                round(sharded["median_ms"], 3), round(streamed["median_ms"], 3)]
            arms["stream_sharded"] = sharded
            print(f"{name}: sharded({sharded['mesh_devices']} dev) "
                  f"{sharded['median_ms']:.1f} ms/batch vs single-device "
                  f"{streamed['median_ms']:.1f} ms/batch | "
                  f"{sharded['plan_builds']} plans for "
                  f"{len(sharded['bucket_keys'])} rungs, "
                  f"{sharded['recompiles']} recompiles")
        if check:  # the compile-once contract + recorded speedup floor
            for arm, r in arms.items():
                _gate(f"{name}/{arm}/recompiles",
                      r["recompiles"] <= r["ladder_bound"],
                      f"{r['recompiles']} recompiles > ladder bound "
                      f"{r['ladder_bound']}")
            _gate(f"{name}/speedup",
                  results[name]["median_per_batch_speedup"] >= SPEEDUP_FLOOR,
                  f"median speedup {results[name]['median_per_batch_speedup']}"
                  f"x < recorded floor {SPEEDUP_FLOOR}x")
            if mesh is not None:
                # a halo export-budget overflow builds the rung's
                # all-gather twin too — one extra plan per overflow is
                # reuse working as designed, not a regression
                bound = (len(sharded["bucket_keys"])
                         + sharded["transport"]["overflows"])
                _gate(f"{name}/plan_builds",
                      sharded["plan_builds"] <= bound,
                      f"{sharded['plan_builds']} plans > "
                      f"{len(sharded['bucket_keys'])} rungs + "
                      f"{sharded['transport']['overflows']} overflows")
    if mesh is not None:
        tr = _run_transport_arm(mesh, tiny=tiny)
        results["transport"] = tr
        print(f"transport: halo steady "
              f"{tr['halo']['steady_median_ms']} ms/batch vs allgather "
              f"{tr['allgather']['steady_median_ms']} ms/batch (ratio "
              f"{tr.get('steady_median_ratio_halo_vs_allgather')}) | "
              f"top-rung export fraction "
              f"{tr.get('top_rung_export_fraction')} ({tr.get('top_rung')}) "
              f"| {tr['halo']['halo_batches']} halo batches, "
              f"{tr['halo']['overflows']} overflows, "
              f"{tr['halo']['plan_builds']} plans for "
              f"{tr['halo']['rungs']} rungs | labels identical: "
              f"{tr['labels_identical']}")
        if check:  # the halo contract + its recorded floors
            _gate("transport/labels", tr["labels_identical"],
                  "halo labels NOT byte-identical to all-gather")
            _gate("transport/plan_builds",
                  tr["halo"]["plan_builds"] <= tr["halo"]["rungs"],
                  f"halo plan_builds {tr['halo']['plan_builds']} > rungs "
                  f"{tr['halo']['rungs']}")
            _gate("transport/overflows", tr["halo"]["overflows"] == 0,
                  f"{tr['halo']['overflows']} export overflows on the "
                  "locality stream (budget regression)")
            ratio = tr.get("steady_median_ratio_halo_vs_allgather")
            _gate("transport/steady_ratio",
                  ratio is not None and ratio <= TRANSPORT_STEADY_RATIO_MAX,
                  f"halo/allgather steady median ratio {ratio} > floor "
                  f"{TRANSPORT_STEADY_RATIO_MAX}")
            frac = tr.get("top_rung_export_fraction")
            _gate("transport/export_fraction",
                  frac is not None
                  and frac <= TRANSPORT_TOP_RUNG_FRACTION_MAX,
                  f"top-rung export fraction {frac} > floor "
                  f"{TRANSPORT_TOP_RUNG_FRACTION_MAX} — halo ships no "
                  "fewer bytes than all-gather")
    be = _run_backend_arm(tiny=tiny)
    results["backend"] = be
    for b in ops.backend_names():
        r = be[b]
        extra = (f" | diff vs ref {r['max_abs_diff_vs_ref']}"
                 if b != "ref" else "")
        print(f"backend {b}: {r['median_ms']} ms/batch "
              f"({r['recompiles']} recompiles ≤ ladder {r['ladder_bound']}"
              f" + {r['backend_overflows']} overflows){extra}")
    if check:  # the registry contract + its recorded floors
        for b in ops.backend_names():
            r = be[b]
            _gate(f"backend/{b}/recompiles",
                  r["recompiles"] <= r["ladder_bound"]
                  + r["backend_overflows"],
                  f"{r['recompiles']} recompiles > ladder "
                  f"{r['ladder_bound']} + {r['backend_overflows']} "
                  "overflows")
            if b != "ref":
                _gate(f"backend/{b}/labels",
                      r["max_abs_diff_vs_ref"] <= BACKEND_MAX_ABS_DIFF,
                      f"max |Δf| vs ref {r['max_abs_diff_vs_ref']} > floor "
                      f"{BACKEND_MAX_ABS_DIFF}")
        _gate("backend/bsr/solved_on_bsr",
              be["bsr"]["bsr_batches"] == be["batches"]
              and be["bsr"]["backend_overflows"] == 0,
              f"{be['bsr']['bsr_batches']}/{be['batches']} batches on bsr, "
              f"{be['bsr']['backend_overflows']} slot-budget overflows "
              "(budget regression)")
    mk = _run_max_k_accuracy(
        n_batches=3 if tiny else 5, per_hub=12 if tiny else 20)
    results["max_k_accuracy"] = mk
    print(f"max_k_accuracy: K {mk['natural_max_K']} -> {mk['capped_max_K']} "
          f"({mk['rungs_untruncated']} -> {mk['rungs_truncated']} rungs) | "
          f"agreement {mk['agreement']:.3f} (floor {mk['agreement_floor']}) | "
          f"accuracy {mk['accuracy_untruncated']:.3f} untruncated / "
          f"{mk['accuracy_truncated']:.3f} truncated")
    if check:
        _gate("max_k/agreement", mk["agreement"] >= MAX_K_AGREEMENT_FLOOR,
              f"agreement {mk['agreement']} < floor {MAX_K_AGREEMENT_FLOOR}")
        # bucket_keys hold the LADDER-padded K, so compare on the rung
        _gate("max_k/ladder", mk["capped_max_K"] <= bucket_k(mk["max_k"]),
              f"capped K {mk['capped_max_K']} > rung "
              f"{bucket_k(mk['max_k'])}")
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {os.path.abspath(out)}")
    if check:
        finish_checks()
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="2x vertices per config")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 600-vertex streams")
    ap.add_argument("--check", action="store_true",
                    help="assert recompiles <= bucket-ladder bound "
                         "(and plan reuse on the sharded arm)")
    ap.add_argument("--out", default=OUT, help="output JSON path")
    args = ap.parse_args()
    main(full=args.full, out=args.out, tiny=args.tiny, check=args.check)

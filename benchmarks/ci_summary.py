"""Render BENCH_stream.json / BENCH_serve.json / BENCH_ingest.json /
BENCH_checkpoint.json / BENCH_landmark.json headline numbers as a
GitHub job-summary markdown table.

The bench-smoke CI job appends this script's stdout to
``$GITHUB_STEP_SUMMARY`` so perf regressions are visible on the PR
checks page without downloading artifacts.  Missing files or keys render
as ``—`` rather than failing: the summary is reporting, the gating lives
in the benchmarks' ``--check``.

Usage: ``python benchmarks/ci_summary.py [BENCH_stream.json]
[BENCH_serve.json] [BENCH_ingest.json] [BENCH_checkpoint.json]
[BENCH_landmark.json]``
"""

from __future__ import annotations

import json
import sys


def _load(path: str) -> dict:
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return {}


def _get(d: dict, *keys, default="—"):
    for k in keys:
        if not isinstance(d, dict) or k not in d:
            return default
        d = d[k]
    return round(d, 3) if isinstance(d, float) else d


def stream_rows(bench: dict) -> list[tuple[str, str]]:
    rows = []
    for cfg in ("ins_heavy_50", "churn_50"):
        rows += [
            (f"{cfg}: stream median ms/batch",
             _get(bench, cfg, "stream", "median_ms")),
            (f"{cfg}: speedup vs naive rebuild",
             f"{_get(bench, cfg, 'median_per_batch_speedup')}x"),
            (f"{cfg}: recompiles (≤ ladder)",
             f"{_get(bench, cfg, 'stream', 'recompiles')} / "
             f"{_get(bench, cfg, 'stream', 'ladder_bound')}"),
        ]
    tr = bench.get("transport", {})
    if tr:
        rows += [
            ("transport: halo steady ms/batch",
             _get(tr, "halo", "steady_median_ms")),
            ("transport: allgather steady ms/batch",
             _get(tr, "allgather", "steady_median_ms")),
            ("transport: halo/allgather ratio",
             _get(tr, "steady_median_ratio_halo_vs_allgather")),
            ("transport: top-rung export fraction",
             f"{_get(tr, 'top_rung_export_fraction')} "
             f"({_get(tr, 'top_rung')})"),
            ("transport: labels byte-identical",
             _get(tr, "labels_identical")),
            ("transport: halo plans / rungs / overflows",
             f"{_get(tr, 'halo', 'plan_builds')} / "
             f"{_get(tr, 'halo', 'rungs')} / "
             f"{_get(tr, 'halo', 'overflows')}"),
        ]
    be = bench.get("backend", {})
    for b in be.get("backends", []):
        r = be.get(b, {})
        detail = (f"{_get(r, 'median_ms')} ms/batch, "
                  f"{_get(r, 'recompiles')} compiles ≤ "
                  f"{_get(r, 'ladder_bound')} + "
                  f"{_get(r, 'backend_overflows')} overflows")
        if b != "ref":  # no raw pipes — they would split the md table cell
            detail += f", max Δf vs ref {_get(r, 'max_abs_diff_vs_ref')}"
        rows.append((f"backend {b}", detail))
    mk = bench.get("max_k_accuracy", {})
    if mk:
        rows.append(("max_k: truncated-vs-free agreement",
                     f"{_get(mk, 'agreement')} "
                     f"(floor {_get(mk, 'agreement_floor')})"))
    return rows


def serve_rows(bench: dict) -> list[tuple[str, str]]:
    rows = []
    for arm in ("serve", "serve_sharded"):
        r = bench.get(arm)
        if not r:
            continue
        rows += [
            (f"{arm}: saturated node lookups/sec",
             _get(r, "node_lookups_per_sec")),
            (f"{arm}: open-loop achieved/offered q/s",
             f"{_get(r, 'open_loop', 'achieved_qps')} / "
             f"{_get(r, 'open_loop', 'offered_qps')}"),
            (f"{arm}: open-loop p50/p99 ms",
             f"{_get(r, 'open_loop', 'latency_ms', 'p50')} / "
             f"{_get(r, 'open_loop', 'latency_ms', 'p99')}"),
            (f"{arm}: read fusion (batches / tickets)",
             f"{_get(r, 'read_batches')} / {_get(r, 'read_tickets')}"),
            (f"{arm}: deadline admissions", _get(r, "deadline_admissions")),
            (f"{arm}: commit p50/p95 ms",
             f"{_get(r, 'mutation_commit_latency_ms', 'p50')} / "
             f"{_get(r, 'mutation_commit_latency_ms', 'p95')}"),
            (f"{arm}: queries while in-flight",
             f"{_get(r, 'queries_while_inflight')} / {_get(r, 'queries')}"),
        ]
    if "sharded_over_single" in bench:
        rows.append(("sharded/single saturated lookup ratio",
                     _get(bench, "sharded_over_single")))
    return rows


def ingest_rows(bench: dict) -> list[tuple[str, str]]:
    rows = []
    for arm in ("host", "device", "sharded"):
        r = bench.get(arm)
        if not r:
            continue
        rows += [
            (f"{arm}: steady mutation ops/sec", _get(r, "ops_per_sec")),
            (f"{arm}: recompiles (≤ ladder)",
             f"{_get(r, 'recompiles')} / {_get(r, 'ladder_bound')}"),
        ]
    if bench:
        rows += [
            ("device vs recorded host-staging reference",
             f"{_get(bench, 'device_over_reference')}x "
             f"(floor {_get(bench, 'floors', 'device_over_reference')}x of "
             f"{_get(bench, 'floors', 'host_staging_ops_per_sec')} ops/s)"),
            ("device vs live host arm",
             f"{_get(bench, 'device_over_host_live')}x"),
            ("kernel-vs-oracle agreement (bit-identical graphs)",
             _get(bench, "agreement")),
            ("ingest jit entries (≤ ladder)",
             f"{_get(bench, 'device', 'ingest_cache_entries')} / "
             f"{_get(bench, 'device', 'ingest_cache_bound')}"),
        ]
    if "sharded" in bench:
        rows += [
            ("sharded vs device arm",
             f"{_get(bench, 'sharded_over_device')}x "
             f"(floor {_get(bench, 'floors', 'sharded_over_device')}x, "
             f"{_get(bench, 'sharded', 'n_devices')} virtual devices)"),
            ("sharded agreement (bit-identical graphs)",
             _get(bench, "agreement_sharded")),
            ("sharded per-device store bytes (≤ 1/D + slack)",
             f"{_get(bench, 'sharded', 'store_device_bytes')} / "
             f"{_get(bench, 'sharded_bytes_per_device_bound')}"),
            ("sharded ingest jit entries (≤ ladder)",
             f"{_get(bench, 'sharded', 'ingest_cache_entries')} / "
             f"{_get(bench, 'sharded', 'ingest_cache_bound')}"),
        ]
    if "locality" in bench:
        rows.append(
            ("locality admission export fraction (vs arrival)",
             f"{_get(bench, 'locality', 'export_fraction')} vs "
             f"{_get(bench, 'locality', 'export_fraction_arrival')} "
             f"(delta {_get(bench, 'locality', 'export_fraction_delta')})"))
    return rows


def checkpoint_rows(bench: dict) -> list[tuple[str, str]]:
    rows = []
    for arm in ("plain", "checkpoint"):
        if arm in bench:
            rows.append((f"{arm}: steady mutation ops/sec",
                         _get(bench, arm, "ops_per_sec")))
    if bench:
        rows += [
            ("checkpoint/plain overhead ratio",
             f"{_get(bench, 'checkpoint_overhead_ratio')} "
             f"(floor {_get(bench, 'floors', 'checkpoint_overhead_ratio')})"),
            ("arms bit-identical graphs", _get(bench, "arms_identical")),
            ("restore latency ms (load / to first commit)",
             f"{_get(bench, 'restore_ms')} / "
             f"{_get(bench, 'restore_to_first_commit_ms')}"),
        ]
        replay = bench.get("restore_replay_identical")
        if isinstance(replay, dict):
            ok = sum(1 for v in replay.values() if v)
            rows.append(("kill points replayed bit-identical",
                         f"{ok} / {len(replay)}"))
    return rows


def landmark_rows(bench: dict) -> list[tuple[str, str]]:
    rows = []
    ag = bench.get("agreement")
    if ag:
        rows += [
            ("hot-set agreement vs exact engine",
             f"{_get(ag, 'hot_agreement')} "
             f"(floor {_get(bench, 'floors', 'hot_agreement')}, "
             f"{_get(ag, 'hot_rows')} rows)"),
            ("overall agreement (hot + cold tail)",
             f"{_get(ag, 'overall_agreement')} over "
             f"{_get(ag, 'unlabeled')} unlabeled"),
            ("accuracy vs truth (exact / landmark)",
             f"{_get(ag, 'acc_exact_vs_truth')} / "
             f"{_get(ag, 'acc_landmark_vs_truth')}"),
            ("cold rows served / landmarks",
             f"{_get(ag, 'landmark', 'cold_rows')} / "
             f"{_get(ag, 'landmark', 'num_landmarks')}"),
        ]
    sc = bench.get("scale")
    if sc:
        rows += [
            ("scale: steady insert rows/sec",
             f"{_get(sc, 'ops_per_sec')} "
             f"(floor {_get(bench, 'floors', 'scale_ops_per_sec')}, "
             f"{_get(sc, 'total_nodes')} nodes)"),
            ("scale: staged hot rung vs exact requirement",
             f"{_get(sc, 'max_hot_bucket_rows')} / "
             f"{_get(sc, 'exact_bucket_rows')} rows "
             f"({_get(sc, 'staged_fraction')}, ceiling "
             f"{_get(bench, 'floors', 'scale_stage_max_fraction')})"),
        ]
    return rows


def main(stream_path: str = "BENCH_stream.json",
         serve_path: str = "BENCH_serve.json",
         ingest_path: str = "BENCH_ingest.json",
         checkpoint_path: str = "BENCH_checkpoint.json",
         landmark_path: str = "BENCH_landmark.json") -> str:
    lines = ["## Benchmark smoke headlines", ""]
    for title, rows in (("stream throughput", stream_rows(_load(stream_path))),
                        ("LP serving", serve_rows(_load(serve_path))),
                        ("device ingestion", ingest_rows(_load(ingest_path))),
                        ("checkpoint / restore",
                         checkpoint_rows(_load(checkpoint_path))),
                        ("landmark backend",
                         landmark_rows(_load(landmark_path)))):
        lines += [f"### {title}", "", "| metric | value |", "|---|---|"]
        if not rows:
            rows = [("(no data)", "—")]
        lines += [f"| {k} | {v} |" for k, v in rows]
        lines.append("")
    return "\n".join(lines)


if __name__ == "__main__":
    args = sys.argv[1:]
    print(main(*args[:5]))

"""Paper Fig. 7: DynLP vs ITLP — iterations and speedup as vertex count and
average degree (kNN k) vary.

Claims under test: (a) ITLP needs more iterations than DynLP in every cell
(it recomputes all labels per batch; DynLP updates only the affected
subgraph with component-informed initialization); (b) the gap grows with
vertex count; (c) iteration count decreases as k grows (denser graph ⇒
shorter hop distances); (d) wall-clock speedup > 1 and grows with size.
"""

from __future__ import annotations

from benchmarks.common import run_stream, spec_for
from repro.core.dynlp import DynLP
from repro.core.itlp import ITLP


def run(sizes=(4_000, 10_000), ks=(3, 5, 7), n_batches=4, delta=1e-4):
    rows = []
    for n in sizes:
        for k in ks:
            spec = spec_for(n, batch=n // n_batches, seed=13)
            dyn = run_stream(DynLP, spec, k=k, delta=delta)
            itl = run_stream(ITLP, spec, k=k, delta=delta)
            rows.append({
                "n": n, "k": k,
                "dynlp_iters": dyn["total_iters"],
                "itlp_iters": itl["total_iters"],
                "dynlp_ms": dyn["total_ms"],
                "itlp_ms": itl["total_ms"],
                "iter_ratio": itl["total_iters"] / max(dyn["total_iters"], 1),
                "speedup": itl["total_ms"] / max(dyn["total_ms"], 1e-9),
                "acc_dynlp": dyn["acc_vs_truth"],
                "acc_itlp": itl["acc_vs_truth"],
            })
    return rows


def main(full: bool = False):
    sizes = (4_000, 10_000, 25_000) if full else (3_000, 8_000)
    rows = run(sizes)
    print("fig7: n,k,dynlp_iters,itlp_iters,iter_ratio,dynlp_ms,itlp_ms,"
          "speedup,acc_dynlp,acc_itlp")
    for r in rows:
        print(f"fig7,{r['n']},{r['k']},{r['dynlp_iters']},{r['itlp_iters']},"
              f"{r['iter_ratio']:.2f},{r['dynlp_ms']:.0f},{r['itlp_ms']:.0f},"
              f"{r['speedup']:.2f},{r['acc_dynlp']:.4f},{r['acc_itlp']:.4f}")
    assert all(r["dynlp_iters"] < r["itlp_iters"] for r in rows), (
        "paper claim: DynLP needs fewer iterations in every experiment")
    return rows


if __name__ == "__main__":
    main()

"""Paper Fig. 8 + Table 3/5 STLP rows: DynLP vs STLP.

Claims under test: STLP's dense harmonic solve is O(U²)-memory bound (the
paper caps it at 50K vertices; our guard raises at the same wall), its
per-batch cost is dominated by the repeated solve, and DynLP overtakes it
as batches accumulate while matching its labels (STLP is exact-harmonic).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_stream, spec_for
from repro.core.dynlp import DynLP
from repro.core.stlp import STLP
from repro.data.synth import accuracy


def run(sizes=(1_000, 3_000, 6_000), n_batches=3, delta=1e-5):
    rows = []
    for n in sizes:
        spec = spec_for(n, batch=n // n_batches, seed=17)
        dyn = run_stream(DynLP, spec, delta=delta)
        stl = run_stream(STLP, spec)
        agree = accuracy(dyn["pred"], stl["pred"])
        rows.append({
            "n": n,
            "dynlp_ms": dyn["total_ms"], "stlp_ms": stl["total_ms"],
            "speedup": stl["total_ms"] / max(dyn["total_ms"], 1e-9),
            "stlp_dense_mb": max(s.dense_bytes for s in stl["stats"]) / 2**20,
            "agreement": agree,
        })
    return rows


def memory_wall():
    """STLP refuses past its dense-memory cap (paper: 50K node wall)."""
    from repro.graph.dynamic import BatchUpdate, DynamicGraph

    g = DynamicGraph(emb_dim=8, k=3)
    eng = STLP(g, max_unlabeled=2_000)
    emb = np.random.default_rng(0).normal(0, 1, (3_000, 8)).astype(np.float32)
    labels = np.full(3_000, -1, np.int8)
    labels[:2] = [0, 1]
    try:
        eng.step(BatchUpdate(ins_emb=emb, ins_labels=labels,
                             del_ids=np.zeros(0, np.int64)))
        return False
    except MemoryError:
        return True


def main(full: bool = False):
    rows = run((1_000, 3_000, 6_000) if full else (800, 2_000))
    print("fig8: n,dynlp_ms,stlp_ms,speedup,stlp_dense_MiB,agreement")
    for r in rows:
        print(f"fig8,{r['n']},{r['dynlp_ms']:.0f},{r['stlp_ms']:.0f},"
              f"{r['speedup']:.2f},{r['stlp_dense_mb']:.1f},{r['agreement']:.4f}")
    assert all(r["agreement"] > 0.97 for r in rows)
    print(f"fig8,memory_wall_enforced,{memory_wall()}")
    return rows


if __name__ == "__main__":
    main()

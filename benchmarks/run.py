"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig7]

Emits ``name,us_per_call,derived`` CSV lines per benchmark (us_per_call is
total wall μs of the benchmark's DynLP runs; derived carries the headline
claim metric), after each benchmark's own detail lines.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from benchmarks import (
    fig5_scaling,
    fig6_delta,
    fig7_itlp,
    fig8_stlp,
    stream_throughput,
    table3_exec,
    table4_batch,
)

BENCHES = {
    "fig5": (fig5_scaling.main, "iterations/time grow with dataset size"),
    "fig6": (fig6_delta.main, "delta controls iterations & accuracy"),
    "fig7": (fig7_itlp.main, "DynLP beats ITLP iterations/speedup"),
    "fig8": (fig8_stlp.main, "DynLP vs STLP + O(U^2) memory wall"),
    "table3": (table3_exec.main, "execution time across datasets"),
    "table4": (table4_batch.main, "method matrix at batch sizes"),
    "stream": (stream_throughput.main,
               "compile-once engine >=3x naive rebuild per batch"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", default=None,
                    choices=("ref", "ell_pallas", "bsr"),
                    help="kernels.ops backend override; Pallas backends fall "
                         "back to interpret=True kernels when no TPU is "
                         "attached instead of crashing")
    args = ap.parse_args()

    if args.backend:
        # Propagate to every DynLP/StreamEngine built downstream; ops
        # resolves interpret=None to True off-TPU, so asking for a Pallas
        # backend on a TPU-less host degrades to the interpreter.
        os.environ["REPRO_BACKEND"] = args.backend
        from repro.kernels import ops
        if args.backend != "ref" and not ops.on_tpu():
            print(f"# no TPU attached: backend={args.backend} runs with "
                  "interpret=True kernels", flush=True)

    failures = 0
    summary = []
    for name, (fn, claim) in BENCHES.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        try:
            fn(full=args.full)
            us = (time.perf_counter() - t0) * 1e6
            summary.append(f"{name},{us:.0f},{claim}")
        except Exception:
            failures += 1
            traceback.print_exc()
            summary.append(f"{name},FAILED,{claim}")
        print(flush=True)
    print("== summary: name,us_per_call,derived ==")
    for line in summary:
        print(line)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
